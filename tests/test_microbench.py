"""Microbench harness tests: the estimator must recover known latencies, and
the methodology invariants from the paper must hold structurally."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.core.microbench import harness, memory


def test_fit_latency_recovers_synthetic_line():
    ks = [4, 16, 64, 256]
    a_true, b_true = 5e-5, 2e-6
    ts = [a_true + b_true * k for k in ks]
    a, b = harness.fit_latency(ks, ts)
    np.testing.assert_allclose(a, a_true, rtol=1e-6)
    np.testing.assert_allclose(b, b_true, rtol=1e-6)


@pytest.mark.slow
def test_chain_result_cpi_curve_converges():
    """The paper's Table I shape: t(K)/(K*t_inf) falls toward 1 as K grows."""
    r = harness.run_chain(harness.OPS["add"], "add",
                          lengths=(4, 16, 64, 256))
    curve = [r.cpi_curve[k] for k in sorted(r.cpi_curve)]
    assert curve[0] >= curve[-1] * 0.8  # monotone-ish down to steady state
    assert 0.5 < curve[-1] < 2.0


@pytest.mark.slow
def test_dependent_not_faster_than_independent_for_heavy_op():
    dep = harness.run_chain(harness.OPS["exp"], "exp", lengths=(8, 32, 128),
                            dependent=True)
    ind = harness.run_chain(harness.OPS["exp"], "exp", lengths=(8, 32, 128),
                            dependent=False)
    # wall-clock on CPU is noisy; assert the *sign* with a generous margin
    assert dep.per_op_s > 0 and ind.per_op_s > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 512), st.integers(0, 1000))
def test_random_cycle_is_single_cycle(n, seed):
    nxt = memory._random_cycle(n, seed)
    seen, i = set(), 0
    for _ in range(n):
        assert i not in seen
        seen.add(i)
        i = int(nxt[i])
    assert i == 0 and len(seen) == n   # returns to start after exactly n hops


def test_chase_measures_positive_latency():
    r = memory.run_chase(16 * 2**10, hop_counts=(64, 256, 1024))
    assert r.per_hop_s > 0


def test_ops_registry_covers_paper_classes():
    # the paper's Table V families: arithmetic, logic, special functions
    have = set(harness.OPS)
    assert {"add", "mul", "fma", "min", "max"} <= have          # arith
    assert {"and", "xor", "popc", "clz"} <= have                # logic/bits
    assert {"rsqrt", "exp", "sin", "tanh", "div"} <= have       # MUFU-class
