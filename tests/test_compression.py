import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.distributed import compression as C


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 10
    q, s = C.quantize_int8(x)
    y = C.dequantize_int8(q, s, x.shape)
    err = np.abs(np.asarray(x - y))
    bound = np.asarray(s).max() / 2 + 1e-6
    assert err.max() <= bound


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
def test_error_feedback_bounded(seed, scale):
    """EF property: the residual never accumulates beyond one quantization
    step's error (it is re-absorbed every round)."""
    g = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (256,)) * scale
    err = jnp.zeros((256,))
    for _ in range(8):
        q, s, err = C.ef_compress_leaf(g, err)
    q_scale = float(np.asarray(s).max())
    assert float(jnp.abs(err).max()) <= q_scale  # one-step error bound


def test_ef_mean_preserved_over_time():
    """Long-run average of dequantized messages converges to the true g."""
    g = jax.random.normal(jax.random.PRNGKey(1), (128,))
    err = jnp.zeros((128,))
    total = jnp.zeros((128,))
    N = 64
    for _ in range(N):
        q, s, err = C.ef_compress_leaf(g, err)
        total = total + C.dequantize_int8(q, s, g.shape)
    np.testing.assert_allclose(np.asarray(total / N), np.asarray(g),
                               atol=2e-2)


def test_compression_ratio_about_4x():
    grads = {"w": jnp.zeros((1024, 1024))}
    r = C.compression_ratio(grads)
    assert 0.2 < r < 0.3  # int8 + scales ~ 26% of f32
