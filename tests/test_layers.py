import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention as A
from repro.models.layers import basic as B


def test_rmsnorm_unit_scale():
    p = B.init_rmsnorm(16)
    x = jnp.ones((2, 3, 16)) * 3.0
    y = B.rmsnorm(p, x)
    np.testing.assert_allclose(np.asarray(y), 1.0, rtol=1e-5)


def test_layernorm_standardizes():
    p = B.init_layernorm(32)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 5 + 3
    y = np.asarray(B.layernorm(p, x), np.float32)
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative_property():
    pos = jnp.arange(8)[None, :]
    sin, cos = B.rope_tables(pos, 32, 10000.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 32))
    y = B.apply_rope(x, sin, cos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # dot products depend only on relative distance
    q = jnp.ones((1, 8, 1, 32))
    k = jnp.ones((1, 8, 1, 32))
    qr = np.asarray(B.apply_rope(q, sin, cos))[0, :, 0]
    kr = np.asarray(B.apply_rope(k, sin, cos))[0, :, 0]
    d01 = qr[1] @ kr[0]
    d34 = qr[4] @ kr[3]
    np.testing.assert_allclose(d01, d34, rtol=1e-5)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = np.asarray(B.softcap(x, 30.0))
    assert np.all(np.abs(y) <= 30.0 + 1e-5)


def test_mask_causal_window_sink():
    qpos = jnp.arange(10)[None, :]
    kpos = jnp.arange(10)[None, :]
    m = np.asarray(A._mask(qpos, kpos, causal=True, window=3, n_sink=2,
                           is_global=False))[0]
    assert not m[2, 5]            # future masked
    assert m[5, 5] and m[5, 3]    # inside window
    assert not m[7, 3]            # outside window
    assert m[9, 0] and m[9, 1]    # sink tokens always visible
    mg = np.asarray(A._mask(qpos, kpos, causal=True, window=3, n_sink=0,
                            is_global=True))[0]
    assert mg[9, 0]               # global layer ignores window


def test_attend_chunked_equals_unchunked():
    rng = np.random.default_rng(0)
    B_, S, H, D = 2, 64, 4, 16
    q = jnp.asarray(rng.normal(size=(B_, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B_, S, 2, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B_, S, 2, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B_, S))
    o1 = A.attend(q, k, v, pos, pos, scale=0.25, chunk=16)
    o2 = A.attend(q, k, v, pos, pos, scale=0.25, chunk=4096)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_attend_padding_path():
    # Sq=60 has no divisor in [16, 32] -> pads to 64 and slices back
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 60, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 60, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 60, 2, 8)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(60)[None], (1, 60))
    o1 = A.attend(q, k, v, pos, pos, scale=0.35, chunk=32)
    o2 = A.attend(q, k, v, pos, pos, scale=0.35, chunk=4096)
    assert o1.shape == (1, 60, 2, 8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    assert np.isfinite(np.asarray(o1)).all()


def test_gqa_matches_explicit_repeat():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 8, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    o1 = A.attend(q, k, v, pos, pos, scale=1.0)
    o2 = A.attend(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2), pos, pos,
                  scale=1.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
