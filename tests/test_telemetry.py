"""Telemetry layer: metrics pipeline, drift -> recalibration, SLO bucket.

Three tiers, cheapest first:

* pure-stdlib units (sink round-trip + loud refusal, drift-detector
  windowing, token-bucket AIMD, quantiles, schema metadata);
* real-``CostModel`` recalibration arithmetic (no jax: the costmodel
  layers are host-side) — pure-data ``Calibration`` rescale, tuning-
  cache invalidation, and the controller's full calibration-path apply
  on a stub engine;
* the acceptance scenarios on the deterministic sim harness (jax on
  CPU): injected drift produces EXACTLY one recalibration event with
  post-recalibration error under the 10% gate and byte-identical
  tokens; burst overload under the token bucket holds the p99 SLO,
  sheds newest-first, and changes no admitted request's tokens.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.autotune.cache import TuningCache, entry_key
from repro.core.costmodel.model import CostModel
from repro.serve.telemetry import (SLO, DriftDetector, MetricsSink,
                                   RequestRecord, StepRecord,
                                   TelemetryController, TokenBucket,
                                   invalidate_tuning_entries,
                                   rescale_calibration, validate_snapshot)
from repro.serve.telemetry.metrics import (REQUEST_FIELDS, STEP_FIELDS,
                                           load_snapshot, quantile,
                                           schema_field_names)


def _step(i=0, **kw):
    base = dict(engine="slot", step=i, t_s=float(i), n_active=2,
                queue_depth=0, predicted_s=1.0, predicted_decode_s=1.0,
                measured_s=1.0, decode_ran=True, n_prefill_units=0,
                bottleneck="memory", budget_s=0.0, host_syncs=i,
                table_uploads=0, blocks_in_use=0, n_blocks=0,
                decoded_tokens=2 * i, preemptions=0, deferred=0,
                kernel_splits=0)
    base.update(kw)
    return StepRecord(**base)


# ---------------------------------------------------------------------------
# metrics pipeline (stdlib only)
# ---------------------------------------------------------------------------


def test_schema_covers_every_record_field():
    assert {f.name for f in STEP_FIELDS} == \
        {f.name for f in dataclasses.fields(StepRecord)}
    assert {f.name for f in REQUEST_FIELDS} == \
        {f.name for f in dataclasses.fields(RequestRecord)}
    for f in STEP_FIELDS + REQUEST_FIELDS:
        assert f.unit and f.engines and f.description
    assert "measured_s" in schema_field_names()


def test_quantile_interpolates():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert quantile(xs, 0.0) == 1.0
    assert quantile(xs, 1.0) == 4.0
    assert quantile(xs, 0.5) == 2.5
    assert quantile([], 0.99) == 0.0


def test_sink_ring_snapshot_roundtrip_and_jsonl(tmp_path):
    sink = MetricsSink(capacity=4)
    for i in range(6):                  # overflow the ring
        sink.record_step(_step(i, measured_s=1.0 + i, kernel_splits=4))
    sink.record_request(RequestRecord("slot", 0, 0.0, 3.0, 3.0, 4, 8))
    assert sink.total_steps == 6 and len(sink.steps()) == 4
    assert sink.steps()[0].step == 2    # oldest fell off

    path = sink.save(tmp_path / "snap.json")
    doc = load_snapshot(path)
    assert doc["kind"] == "telemetry_snapshot"
    assert len(doc["steps"]) == 4
    # the resolved split-KV factor survives the snapshot round-trip
    assert all(s["kernel_splits"] == 4 for s in doc["steps"])
    assert doc["summary"]["steps"] == 6
    assert doc["summary"]["request_p99_s"] == 3.0
    # the snapshot carries its own schema table
    assert {f["name"] for f in doc["schema"]["step"]} == \
        {f.name for f in STEP_FIELDS}

    out = sink.export_jsonl(tmp_path / "log.jsonl")
    lines = [json.loads(line) for line in
             out.read_text().strip().splitlines()]
    assert [ln["record"] for ln in lines] == ["step"] * 4 + ["request"]


def test_snapshot_loud_refusal():
    with pytest.raises(ValueError, match="not a telemetry snapshot"):
        validate_snapshot({"entries": {}})          # kind-less JSON
    with pytest.raises(ValueError, match="newer than supported"):
        validate_snapshot({"kind": "telemetry_snapshot", "version": 99})


# ---------------------------------------------------------------------------
# drift detector
# ---------------------------------------------------------------------------


def test_drift_fires_once_past_gate_then_cools_down():
    d = DriftDetector(0.10, window=6, min_samples=4, cooldown=5)
    events = [d.observe("decode", "b4", 1.0, 2.0) for _ in range(10)]
    fired = [e for e in events if e is not None]
    assert len(fired) == 1              # window reset + cooldown
    assert events[3] is not None        # exactly at min_samples
    ev = fired[0]
    assert ev.kind == "decode" and ev.bucket == "b4"
    assert ev.ratio == pytest.approx(2.0) and ev.error == pytest.approx(1.0)
    assert d.events == fired


def test_drift_median_resists_one_outlier_and_in_gate_is_quiet():
    d = DriftDetector(0.10, window=8, min_samples=4)
    for _ in range(7):
        assert d.observe("decode", "b4", 1.0, 1.02) is None
    # one preempted/compacted outlier step must not fake a drift
    assert d.observe("decode", "b4", 1.0, 9.0) is None
    assert d.error("decode", "b4") < 0.10


def test_drift_skips_unpriceable_samples():
    d = DriftDetector(window=4, min_samples=2)
    for _ in range(8):
        assert d.observe("decode", "b4", 0.0, 1.0) is None   # no model
    assert d.error("decode", "b4") is None


# ---------------------------------------------------------------------------
# SLO token bucket
# ---------------------------------------------------------------------------


def test_token_bucket_refill_burst_and_spend_floor():
    b = TokenBucket(SLO(target_p99_s=1.0), burst_factor=2.0)
    assert b.begin_step() == pytest.approx(2.0)     # full + refill -> burst
    b.spend(5.0)                                    # overdraft floors at 0
    assert b.budget_s == 0.0
    assert b.begin_step() == pytest.approx(1.0)     # one refill


def test_token_bucket_aimd_adapts_rate():
    slo = SLO(target_p99_s=1.0, window=4, increase=0.1, decrease=0.5)
    b = TokenBucket(slo)
    for _ in range(4):
        b.observe(2.0)                              # violated window
    assert b.violations == 1 and b.rate_s == pytest.approx(0.5)
    for _ in range(4):
        b.observe(0.1)                              # healthy window
    assert b.windows == 2 and b.rate_s == pytest.approx(0.6)
    assert b.rate_trace == [pytest.approx(0.5), pytest.approx(0.6)]


def test_token_bucket_rate_floor_prevents_starvation():
    slo = SLO(target_p99_s=1.0, window=2, decrease=0.5, min_rate_s=0.25)
    b = TokenBucket(slo)
    for _ in range(20):
        b.observe(9.0)
    assert b.rate_s == pytest.approx(0.25)          # floored, not 0


# ---------------------------------------------------------------------------
# recalibration over the REAL cost model (host-side, no jax)
# ---------------------------------------------------------------------------


def test_rescale_calibration_scales_the_implicated_term():
    model = CostModel.from_named("tpu_v5e")
    mem_census = {"flops": 1e6, "hbm_bytes": 1e9}
    mxu_census = {"flops": 1e15, "hbm_bytes": 1.0}
    base_mem = model.predict(mem_census)
    base_mxu = model.predict(mxu_census)
    assert base_mem.bottleneck == "memory"
    assert base_mxu.bottleneck == "compute"

    slow_mem = CostModel(rescale_calibration(model.cal, 2.0,
                                             bottleneck="memory"))
    assert slow_mem.predict(mem_census).memory_s == \
        pytest.approx(2.0 * base_mem.memory_s)
    # the compute surface is untouched on the memory path
    assert slow_mem.predict(mxu_census).compute_s == \
        pytest.approx(base_mxu.compute_s)

    slow_mxu = CostModel(rescale_calibration(model.cal, 3.0,
                                             bottleneck="compute"))
    assert slow_mxu.predict(mxu_census).compute_s == \
        pytest.approx(3.0 * base_mxu.compute_s)

    # pure-data update: the source calibration is never mutated
    assert model.predict(mem_census).memory_s == \
        pytest.approx(base_mem.memory_s)
    assert rescale_calibration(model.cal, 2.0).name.endswith("+recal")
    with pytest.raises(ValueError, match="positive"):
        rescale_calibration(model.cal, 0.0)


def test_invalidate_tuning_entries_by_calibration_id():
    cache = TuningCache(path=None)
    k_stale = entry_key("matmul", "m128", "bf16", "cpu", "tpu_v5e")
    k_other = entry_key("matmul", "m128", "bf16", "cpu", "fresh")
    cache.put(k_stale, {"config": {"bm": 128}})
    cache.put(k_other, {"config": {"bm": 256}})
    assert invalidate_tuning_entries(cache, calibration_id="tpu_v5e") == 1
    assert cache.get(k_stale) is None and cache.get(k_other) is not None
    # None = conservative drop-everything
    assert invalidate_tuning_entries(cache, calibration_id=None) == 1
    assert len(cache) == 0


class _StubEngine:
    """Just enough engine surface for the controller's calibration path."""
    max_batch = 4

    def __init__(self, cost_model, autotuner=None):
        self.cost_model = cost_model
        self.autotuner = autotuner
        self._pred_cache = {"stale": object()}

    def set_cost_model(self, cm):
        self.cost_model = cm
        self._pred_cache.clear()


class _StubTuner:
    def __init__(self, cost_model, cache):
        self.cost_model = cost_model
        self.cache = cache


def test_controller_applies_calibration_recalibration_end_to_end():
    """Real CostModel (no ``rescale`` protocol): a drift event must swap
    in a rescaled calibration, clear the engine's prediction cache, drop
    the stale tuning entries, and repoint the autotuner — all recorded
    in the RecalibrationEvent."""
    cm = CostModel.from_named("tpu_v5e")
    cache = TuningCache(path=None)
    cache.put(entry_key("paged_attention", "b4", "bf16", "cpu",
                        cm.cal.name), {"config": {"block_size": 16}})
    cache.put(entry_key("paged_attention", "b4", "bf16", "cpu",
                        "unrelated"), {"config": {"block_size": 32}})
    engine = _StubEngine(cm, _StubTuner(cm, cache))
    ctl = TelemetryController(
        drift=DriftDetector(0.10, window=4, min_samples=3))
    ctl.bind(engine)

    mem = {"flops": 1e6, "hbm_bytes": 1e9}
    base = cm.predict(mem)
    for i in range(3):
        ctl.on_step(_step(i, predicted_decode_s=1e-3, measured_s=2e-3))
    assert len(ctl.recalibrations) == 1
    ev = ctl.recalibrations[0]
    assert ev.applied == "calibration"
    assert ev.calibration_before == "tpu_v5e"
    assert ev.calibration_after.endswith("+recal")
    assert ev.invalidated == 1                     # only the stale entry
    assert cache.get(entry_key("paged_attention", "b4", "bf16", "cpu",
                               "unrelated")) is not None
    assert engine.cost_model is not cm             # swapped, not mutated
    assert engine.autotuner.cost_model is engine.cost_model
    assert engine._pred_cache == {}                # re-prices next step
    # record said memory-bound, ratio 2: the new model predicts ~2x
    assert engine.cost_model.predict(mem).memory_s == \
        pytest.approx(2.0 * base.memory_s)
    assert ctl.sink.events() == ctl.recalibrations


def test_controller_observe_only_mode_records_but_never_applies():
    cm = CostModel.from_named("tpu_v5e")
    engine = _StubEngine(cm)
    ctl = TelemetryController(
        drift=DriftDetector(0.10, window=4, min_samples=3),
        recalibrate=False)
    ctl.bind(engine)
    for i in range(3):
        ctl.on_step(_step(i, predicted_decode_s=1e-3, measured_s=2e-3))
    assert len(ctl.recalibrations) == 1
    assert ctl.recalibrations[0].applied == "none"
    assert engine.cost_model is cm


def test_controller_rejects_double_bind_and_bad_slo():
    ctl = TelemetryController(drift=False)
    ctl.bind(_StubEngine(None))
    with pytest.raises(ValueError, match="already bound"):
        ctl.bind(_StubEngine(None))
    with pytest.raises(TypeError, match="SLO or TokenBucket"):
        TelemetryController(slo=3.5)


def test_mixed_steps_never_feed_drift():
    """A step with both decode and prefill units is attribution-
    ambiguous and must not produce drift samples."""
    ctl = TelemetryController(
        drift=DriftDetector(0.10, window=4, min_samples=1))
    ctl.bind(_StubEngine(None))
    for i in range(8):
        ctl.on_step(_step(i, n_prefill_units=2, decode_ran=True,
                          predicted_decode_s=1e-3, measured_s=1.0))
    assert ctl.recalibrations == []


# ---------------------------------------------------------------------------
# acceptance scenarios on the sim harness (jax, CPU)
# ---------------------------------------------------------------------------


def test_drift_scenario_exactly_one_event_restores_error_and_tokens():
    from repro.serve.telemetry.scenarios import run_drift_scenario
    res = run_drift_scenario(drift_factor=2.0)
    assert res["n_events"] == 1                    # exactly one, not a storm
    assert res["pre_error"] > 0.10                 # the injected drift
    assert res["post_error"] < 0.10                # restored under the gate
    assert res["post_samples"] >= 4
    assert res["rescales"] == [("decode", pytest.approx(2.0))]
    assert res["tokens_ok"]                        # recalibration is
    assert res["completed"] == res["n_requests"]   # invisible to outputs


def test_overload_scenario_holds_slo_and_sheds_newest_first():
    from repro.serve.telemetry.scenarios import run_overload_scenario
    res = run_overload_scenario(load_factor=2)
    assert res["slo_held"]                         # p99 <= target at 2x load
    assert res["baseline_violates"]                # ungated would spike
    assert res["deferred"] > 0                     # newest actually shed
    assert res["admission_fifo"]                   # oldest protected
    assert res["tokens_ok"]
    assert res["completed"] == res["n_requests"]


def test_engine_reprices_after_set_cost_model_post_compile():
    """Regression for the Compiled-has-no-lower trap: after the first
    ``_predict_decode`` the decode fn is an AOT executable; swapping the
    cost model (which clears the prediction cache) must re-price from
    the stored HLO text, not crash re-lowering — and the new price must
    actually take effect in admission."""
    from repro.serve.engine import PagedServingEngine
    from repro.serve.sim import FakeCostModel, FakeModel, SimClock
    cm = FakeCostModel(decode_s=1.0, prefill_s=1.0)
    eng = PagedServingEngine(FakeModel(), params=None, clock=SimClock(),
                             max_batch=2, max_len=32, block_size=4,
                             chunk_size=4, cost_model=cm)
    eng.submit(np.asarray([3, 4, 5], np.int32), max_new_tokens=3)
    eng.step()
    assert eng._predict_decode().step_s == 1.0
    cm.rescale("decode", 2.5)
    eng.set_cost_model(cm)
    assert eng._predict_decode().step_s == 2.5     # re-priced, no re-lower
    eng.run_until_done()
    assert eng.stats.completed == 1
