"""The fused decode hot path's acceptance bar.

Four contracts, each pinned directly:

* **token equality** — the fused path (on-device sampling, donated
  caches, one-step-ahead pipelining) reproduces the legacy blocking
  engines' greedy token ids byte-for-byte on the 32-request acceptance
  trace, on BOTH engines;
* **<= 1 host sync per step** — the transfer-counting hook
  (``EngineStats.host_syncs``) stays at or under one device->host
  transfer per engine step, and the whole serve loop runs under
  ``jax.transfer_guard_device_to_host("disallow")``, so any stray
  implicit transfer (the legacy paths' ``[B, vocab]`` logit pulls) is a
  hard error, not a missed count;
* **use-after-donate** — a fused step consumes its cache operand: the
  pre-step buffers are deleted, reading them raises, and the engine
  keeps decoding correctly on the donated successor;
* **no second cache materialization** — across a whole serve,
  ``kv_cache_bytes()`` is constant and the live-buffer census finds
  exactly ONE array of the pool's shape alive (the legacy functional
  path holds two at its peak).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.zoo import build_model
from repro.serve import PagedServingEngine, ServingEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(ARCHS["gemma2-2b"], n_layers=2, vocab_size=128)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _trace(cfg, n_req=32, seed=11, max_prompt=31):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(1, max_prompt))
                         ).astype(np.int32) for _ in range(n_req)]


def _run(eng, prompts, max_new=4):
    rids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_until_done(max_steps=20_000)
    return [eng.done[r].tokens for r in rids]


# ---------------------------------------------------------------------------
# token equality: fused == legacy, both engines, the acceptance trace
# ---------------------------------------------------------------------------


def test_fused_slot_engine_tokens_identical_on_acceptance_trace(tiny):
    cfg, model, params = tiny
    prompts = _trace(cfg)
    base = _run(ServingEngine(model, params, max_batch=4, max_len=48,
                              fused=False), prompts)
    fused = _run(ServingEngine(model, params, max_batch=4, max_len=48,
                               fused=True), prompts)
    assert fused == base


def test_fused_paged_engine_tokens_identical_on_acceptance_trace(tiny):
    cfg, model, params = tiny
    prompts = _trace(cfg)
    base = _run(PagedServingEngine(model, params, max_batch=4, max_len=48,
                                   block_size=8, n_blocks=10, chunk_size=8,
                                   fused=False), prompts)
    eng = PagedServingEngine(model, params, max_batch=4, max_len=48,
                             block_size=8, n_blocks=10, chunk_size=8,
                             fused=True)
    fused = _run(eng, prompts)
    assert fused == base
    eng.allocator.check()
    assert eng.allocator.n_free == eng.n_blocks     # still leak-free


def test_fused_paged_engine_eos_and_eviction_paths(tiny):
    """The lagged-retirement paths: eos mid-stream and pool-pressure
    eviction replays must still match the legacy engine exactly."""
    cfg, model, params = tiny
    prompts = _trace(cfg, n_req=8, seed=5, max_prompt=28)
    kw = dict(max_batch=4, max_len=48, block_size=8, n_blocks=6,
              chunk_size=8)

    def run(fused):
        eng = PagedServingEngine(model, params, fused=fused, **kw)
        rids = [eng.submit(p, max_new_tokens=5, eos_id=7) for p in prompts]
        eng.run_until_done(max_steps=20_000)
        return eng, [eng.done[r].tokens for r in rids]

    b_eng, base = run(False)
    f_eng, fused = run(True)
    assert fused == base
    assert f_eng.stats.preemptions > 0              # pool pressure exercised
    assert f_eng.stats.completed == 8


# ---------------------------------------------------------------------------
# the transfer-counting hook: <= 1 device->host sync per engine step
# ---------------------------------------------------------------------------


def test_fused_paths_sync_at_most_once_per_step_under_transfer_guard(tiny):
    """Counted AND enforced: ``host_syncs`` (every explicit device_get
    the engines make) stays <= steps, while the transfer guard turns any
    uncounted implicit device->host copy into an error."""
    cfg, model, params = tiny
    prompts = _trace(cfg, n_req=10, seed=3)
    slot = ServingEngine(model, params, max_batch=4, max_len=48, fused=True)
    paged = PagedServingEngine(model, params, max_batch=4, max_len=48,
                               block_size=8, n_blocks=12, chunk_size=8,
                               fused=True)
    with jax.transfer_guard_device_to_host("disallow"):
        for eng in (slot, paged):
            for p in prompts:
                eng.submit(p, max_new_tokens=4)
            eng.run_until_done(max_steps=20_000)
    for eng in (slot, paged):
        assert eng.stats.completed == 10
        assert eng.stats.steps > 0
        assert eng.stats.host_syncs <= eng.stats.steps, (
            eng.stats.host_syncs, eng.stats.steps)


def test_legacy_paths_sync_more_than_once_per_step(tiny):
    """The baseline the hook exists to expose: the blocking engines pull
    logits every decode step AND every prefill/final-chunk, so their
    sync rate is strictly above one per step on a trace with prefills."""
    cfg, model, params = tiny
    prompts = _trace(cfg, n_req=8, seed=3)
    slot = ServingEngine(model, params, max_batch=4, max_len=48,
                         fused=False)
    _run(slot, prompts)
    assert slot.stats.host_syncs > slot.stats.steps


def test_paged_block_tables_upload_only_on_mutation(tiny):
    """The satellite fix: the device block-table copy is cached and
    re-uploaded only when growth/retire/eviction/compaction actually
    mutates a table row — not rebuilt fresh every step."""
    cfg, model, params = tiny
    eng = PagedServingEngine(model, params, max_batch=4, max_len=64,
                             block_size=16, n_blocks=16, chunk_size=8,
                             fused=True)
    prompts = _trace(cfg, n_req=4, seed=2, max_prompt=8)
    _run(eng, prompts, max_new=24)
    assert eng.stats.table_uploads > 0
    # a long decode mostly runs WITHIN blocks: uploads happen on growth/
    # retire/compaction steps only, far fewer than the step count
    assert eng.stats.table_uploads < eng.stats.steps / 2, (
        eng.stats.table_uploads, eng.stats.steps)


# ---------------------------------------------------------------------------
# donation: use-after-donate guard + no second cache materialization
# ---------------------------------------------------------------------------


def test_fused_step_donates_cache_and_use_after_donate_raises(tiny):
    cfg, model, params = tiny
    eng = ServingEngine(model, params, max_batch=2, max_len=32, fused=True)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=4)
    old = jax.tree.leaves(eng.cache)
    eng.step()                       # prefill splice + decode, both donated
    assert all(x.is_deleted() for x in old)
    with pytest.raises(RuntimeError):
        jax.device_get(old[0])
    # and the engine still decodes correctly on the donated successor
    eng.run_until_done()
    assert eng.stats.completed == 1


def test_fused_paged_step_donates_pool(tiny):
    cfg, model, params = tiny
    eng = PagedServingEngine(model, params, max_batch=2, max_len=32,
                             block_size=8, chunk_size=8, fused=True)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=4)
    old = jax.tree.leaves(eng.cache)
    eng.step()                       # the chunk call donates the pool
    assert all(x.is_deleted() for x in old)
    eng.run_until_done()
    assert eng.stats.completed == 1


def test_no_second_cache_alive_and_kv_bytes_flat(tiny):
    """Live-buffer census: at every step boundary of a fused serve,
    exactly one pool-shaped array is alive — the in-place successor —
    and ``kv_cache_bytes()`` never moves.  (The legacy path necessarily
    holds old + new caches at its peak; donation is what removes the
    second residency.)"""
    cfg, model, params = tiny
    eng = PagedServingEngine(model, params, max_batch=4, max_len=48,
                             block_size=8, n_blocks=10, chunk_size=8,
                             fused=True)
    pool_shape = jax.tree.leaves(eng.cache)[0].shape
    kv0 = eng.kv_cache_bytes()
    for p in _trace(cfg, n_req=6, seed=7):
        eng.submit(p, max_new_tokens=4)
    for _ in range(200):
        active = eng.step()
        live = [a for a in jax.live_arrays()
                if a.shape == pool_shape and not a.is_deleted()]
        assert len(live) == 2, len(live)     # the k pool + the v pool
        assert eng.kv_cache_bytes() == kv0
        if active == 0 and not eng.queue:
            break
    assert eng.stats.completed == 6


# ---------------------------------------------------------------------------
# the engine-facing decode_step head
# ---------------------------------------------------------------------------


def test_model_decode_step_matches_decode_argmax(tiny):
    """``Model.decode_step`` is decode + last-pos argmax, on device."""
    cfg, model, params = tiny
    B, S = 2, 8
    cache = model.init_cache(B, 16)
    logits, cache1 = model.prefill(
        params, {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
                 % cfg.vocab_size}, max_len=16)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    lg, _ = model.decode(params, cache1, toks[:, None], pos)
    want = jnp.argmax(lg, axis=-1)
    got, _ = model.decode_step(params, cache1, toks[:, None], pos)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
