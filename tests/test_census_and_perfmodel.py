"""core/isa + cost-model tests: census FLOPs/trip-count correctness on
real compiled modules, the collective parser on canned SPMD HLO, and the
paper-table consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import CostModel, validate_against_paper
from repro.core.isa import hlo_census as hc
from repro.core.microbench import tables
from repro.core.perfmodel.hardware import TPU_V5E


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_census_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 96), jnp.float32)
    text = _compiled_text(lambda x, y: x @ y, a, b)
    c = hc.census(text)
    assert c["flops"] == 2 * 64 * 96 * 128


def test_census_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ x * 0.001, ()
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    text = _compiled_text(f, x)
    c = hc.census(text)
    one = 2 * 32 * 32 * 32
    assert c["flops"] >= 10 * one * 0.99, c["flops"]
    assert 10 in c["while_trips"].values()


def test_census_memory_dynamic_slice_not_overcounted():
    big = jax.ShapeDtypeStruct((100, 1024), jnp.float32)

    def f(x):
        def body(c, i):
            return c + jax.lax.dynamic_index_in_dim(x, i, keepdims=False), ()
        out, _ = jax.lax.scan(body, jnp.zeros((1024,)),
                              jnp.arange(100, dtype=jnp.int32))
        return out

    text = _compiled_text(f, big)
    c = hc.census(text)
    full = 100 * 1024 * 4
    # each iteration should charge ~1 row (4KB), not the full 400KB array
    assert c["hbm_bytes"] < 40 * full


_CANNED = """
HloModule canned, num_partitions=8

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%p0), replica_groups=[1,8]<=[8], to_apply=%add
  %ag = f32[64,1024]{1,0} all-gather(%ar), replica_groups=[1,8]<=[8], dimensions={1}
  %rs = f32[64,16]{1,0} reduce-scatter(%p0), replica_groups=[2,4]<=[8], dimensions={1}, to_apply=%add
  %cp = f32[64,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[64,128]{1,0} add(%cp, %p0)
}
"""


def test_collective_parser_wire_bytes():
    rows = {r["op"]: r for r in hc.collective_table(_CANNED, n_devices=8)}
    b = 64 * 128 * 4
    assert rows["ar"]["kind"] == "all-reduce"
    np.testing.assert_allclose(rows["ar"]["wire_bytes"], 2 * b * 7 / 8)
    np.testing.assert_allclose(rows["ag"]["wire_bytes"], 64 * 1024 * 4 * 7 / 8)
    assert rows["rs"]["group"] == 4
    np.testing.assert_allclose(rows["rs"]["wire_bytes"], b * 3 / 4)
    np.testing.assert_allclose(rows["cp"]["wire_bytes"], b)


def test_op_mapping_table():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    lowered = jax.jit(lambda x: jnp.tanh(x @ x)).lower(a)
    m = hc.op_mapping_table(lowered.as_text(),
                            lowered.compile().as_text())
    assert m["n_source_ops"] > 0 and m["n_optimized_ops"] > 0
    assert "dot" in m["optimized"] or "fusion" in m["optimized"]


def test_paper_table_consistency():
    t = tables.ampere_table()
    checks = validate_against_paper(t)
    assert all(checks.values()), {k: v for k, v in checks.items() if not v}


def test_costmodel_terms():
    census = {"flops": 197e12, "hbm_bytes": 0.0,
              "collective_bytes_total": 200e9 * 1.0,
              "op_histogram": {"fusion": 1000, "dot": 100}}
    model = CostModel.from_table(tables.v5e_table(), hw=TPU_V5E)
    p = model.predict(census, mem_bytes=819e9)
    np.testing.assert_allclose(p.compute_s, 1.0)
    np.testing.assert_allclose(p.memory_s, 1.0)
    np.testing.assert_allclose(p.collective_s, 1.0)
    assert p.step_s >= 1.0
    assert p.issue_overhead_s > 0


def test_predictor_compat_shim():
    # compat-shim coverage: the OLD perfmodel.predictor entry points must
    # keep answering (new code imports repro.core.costmodel directly)
    from repro.core.perfmodel import predictor
    census = {"flops": 197e12, "hbm_bytes": 0.0,
              "collective_bytes_total": 200e9 * 1.0,
              "op_histogram": {"fusion": 1000, "dot": 100}}
    p = predictor.predict(census, mem_bytes_analytic=819e9,
                          table=tables.v5e_table())
    np.testing.assert_allclose(p.compute_s, 1.0)
    np.testing.assert_allclose(p.memory_s, 1.0)
    assert predictor.issue_overhead(census["op_histogram"],
                                    tables.v5e_table()) > 0


def test_v5e_table_peaks_match_hardware_spec():
    t = tables.v5e_table()
    assert t["mxu"]["bf16.f32"]["peak_tflops"] * 1e12 == TPU_V5E.peak_flops_bf16
    assert t["memory"]["hbm_bandwidth_gbs"] * 1e9 == TPU_V5E.hbm_bandwidth
