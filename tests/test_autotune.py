"""The autotune subsystem: candidate generation + pruning, deterministic
analytic ranking for all four tunable kernels, persistent-cache round-trip,
the tune/show/export CLI, the kernels' config dispatch path, and tuned-config
numerical equivalence against the ref.py oracles (decode-equivalence
tolerances)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.autotune import (Autotuner, TuningCache, get_tunable,
                                 shape_bucket, tunable_names)
from repro.core.autotune.cache import entry_key, split_key, validate
from repro.core.autotune.cli import main as autotune_main
from repro.core.costmodel import CostModel
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _no_global_tuner():
    """Tests must not leak an installed autotuner into each other."""
    prev = autotune.install(None)
    yield
    autotune.install(prev)


@pytest.fixture(scope="module")
def cm():
    return CostModel.from_named("tpu_v5e")


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------

def test_candidates_are_aligned_and_deduped():
    tn = get_tunable("flash_attention")
    shapes = {"seq_q": 512, "seq_kv": 512}
    cands = tn.candidates(shapes, "bf16")
    assert cands
    seen = set()
    for c in cands:
        key = tuple(sorted(c.items()))
        assert key not in seen
        seen.add(key)
        # MXU/VPU-aligned ladder values only, clamped to the problem
        assert c["block_q"] in (8, 16, 32, 64, 128, 256, 512)
        assert c["block_k"] in (8, 16, 32, 64, 128, 256, 512)


def test_candidates_prune_against_vmem_budget():
    tn = get_tunable("ssm_scan")
    shapes = {"batch": 4, "seq": 512, "d_inner": 2048, "state_dim": 16}
    wide = tn.candidates(shapes, "bf16", budget_bytes=1e12)
    tight = tn.candidates(shapes, "bf16", budget_bytes=1e5)
    assert len(tight) < len(wide)
    # the default config must survive any budget (it is what launches)
    assert tn.effective_default(shapes) in tight


def test_divisor_spaces_always_launchable():
    tn = get_tunable("wkv6")
    shapes = {"heads": 12}   # not a power of two
    for c in tn.candidates(shapes, "bf16"):
        assert 12 % c["block_h"] == 0


def test_paged_candidates_dedupe_after_clamp():
    """Regression: at a small context the split ladder (1,2,4,8,16) and
    large block sizes all clamp onto the same few configs — dedupe must
    run on the CLAMPED config, not the raw ladder product, or the
    candidate list carries duplicates that analytic search ranks (and
    measured search times) repeatedly."""
    tn = get_tunable("paged_attention")
    shapes = tn.normalize_shapes({"ctx": 24})
    cands = tn.candidates(shapes, "f32")
    keys = [tuple(sorted(c.items())) for c in cands]
    assert len(keys) == len(set(keys)), "clamped candidates not deduped"


def test_paged_num_splits_never_exceeds_page_count():
    tn = get_tunable("paged_attention")
    shapes = tn.normalize_shapes({"ctx": 64})
    for c in tn.candidates(shapes, "f32"):
        n_pages = -(-64 // c["block_size"])
        assert 1 <= c["num_splits"] <= n_pages, c


def test_paged_ctx_buckets_tune_independently(cm):
    """Short and long contexts land in different cache entries, so the
    split factor tuned for ctx=4096 never leaks onto ctx=256 decodes."""
    tuner = Autotuner(cm)
    k_short = tuner.key_for("paged_attention", {"ctx": 256})
    k_long = tuner.key_for("paged_attention", {"ctx": 4096})
    assert k_short != k_long
    assert "ctx256" in split_key(k_short)[1]
    assert "ctx4096" in split_key(k_long)[1]


def test_paged_split_crossover_matches_lane_model(cm):
    """The analytic cost model must predict the split-KV crossover: a
    lane-starved long-context decode (B*H grid cells < n_cores) tunes to
    num_splits > 1, while the default batch-heavy shapes (cells >= lanes,
    so splitting only adds merge traffic) stay unsplit."""
    tuner = Autotuner(cm)
    longctx = tuner.tune("paged_attention",
                         {"batch": 1, "heads": 4, "kv_heads": 2,
                          "head_dim": 128, "ctx": 4096})
    assert longctx.best["num_splits"] > 1
    default = tuner.tune("paged_attention")
    assert default.best["num_splits"] == 1


def test_unknown_shape_key_is_an_error():
    with pytest.raises(KeyError):
        get_tunable("mxu_probe").normalize_shapes({"bogus": 3})


def test_lookup_unknown_kernel_vs_bad_shapes(cm):
    tuner = Autotuner(cm)
    # non-tunable kernels quietly resolve to None (dispatch fallback) ...
    assert tuner.lookup("alu_chain", {}) is None
    # ... but a typo'd axis on a KNOWN tunable stays loud
    with pytest.raises(KeyError):
        tuner.lookup("flash_attention", {"seq": 64})


def test_low_precision_axis_is_opt_in():
    tn = get_tunable("flash_attention")
    shapes = {"seq_q": 256, "seq_kv": 256}
    default_accs = {c["acc_dtype"] for c in tn.candidates(shapes, "bf16")}
    assert default_accs == {"f32"}
    opened = {c["acc_dtype"]
              for c in tn.candidates(shapes, "bf16",
                                     allow_low_precision=True)}
    assert opened == {"f32", "bf16"}


# ---------------------------------------------------------------------------
# analytic search: deterministic, all four kernels, no device
# ---------------------------------------------------------------------------

def test_analytic_tune_all_four_kernels_ranked(cm):
    tuner = Autotuner(cm)
    results = tuner.tune_all()
    assert sorted(results) == tunable_names()
    for name, res in results.items():
        assert res.source == "analytic"
        assert len(res.ranked) >= 2, name
        ts = [r["predicted_s"] for r in res.ranked]
        assert ts == sorted(ts)
        assert all(t > 0 for t in ts)
        assert res.predicted_best_s <= res.predicted_default_s
        assert res.predicted_speedup >= 1.0


def test_analytic_tune_is_deterministic(cm):
    a = Autotuner(cm).tune("flash_attention")
    b = Autotuner(CostModel.from_named("tpu_v5e")).tune("flash_attention")
    assert a.best == b.best
    assert a.key == b.key
    assert [r["config"] for r in a.ranked] == [r["config"] for r in b.ranked]
    np.testing.assert_allclose([r["predicted_s"] for r in a.ranked],
                               [r["predicted_s"] for r in b.ranked])


def test_tuning_is_calibration_sensitive_in_the_key(cm):
    """Two calibrations never share cache entries."""
    t1 = Autotuner(cm)
    t2 = Autotuner(CostModel.from_named("ampere_a100"))
    k1 = t1.key_for("wkv6", {})
    k2 = t2.key_for("wkv6", {})
    assert k1 != k2
    assert split_key(k1)[4] == "tpu_v5e"
    assert split_key(k2)[4] == "ampere_a100"


def test_shape_bucket_rounds_up_to_pow2():
    assert shape_bucket({"seq": 100, "batch": 2}) == "batch2_seq128"
    # nearby shapes share a bucket -> one tuning entry serves both
    assert shape_bucket({"seq": 65}) == shape_bucket({"seq": 128})


# ---------------------------------------------------------------------------
# cache persistence
# ---------------------------------------------------------------------------

def test_cache_round_trips_losslessly(tmp_path, cm):
    path = tmp_path / "cache.json"
    tuner = Autotuner(cm, TuningCache(path))
    res = tuner.tune("ssm_scan")
    reloaded = TuningCache(path)
    assert len(reloaded) == 1
    entry = reloaded.get(res.key)
    assert entry is not None
    assert entry == tuner.cache.get(res.key)
    assert entry["config"] == res.best
    # a fresh autotuner over the reloaded cache serves the tuned config
    fresh = Autotuner(CostModel.from_named("tpu_v5e"), reloaded)
    assert fresh.lookup("ssm_scan", {}) == res.best
    assert fresh.stats.hits == 1


def test_cache_refuses_newer_schema(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"kind": "autotune_cache", "version": 99,
                             "entries": {}}))
    with pytest.raises(ValueError, match="newer"):
        TuningCache(p)


def test_cache_key_is_five_component(cm):
    key = entry_key("k", "b", "bf16", "dev", "cal")
    assert split_key(key) == ("k", "b", "bf16", "dev", "cal")
    with pytest.raises(ValueError):
        entry_key("k|bad", "b", "bf16", "dev", "cal")
    with pytest.raises(ValueError):
        split_key("only|three|parts")


def test_cache_validate_migrates_older_version():
    doc = validate({"kind": "autotune_cache", "version": 0,
                    "entries": {"whatever": {}}})
    assert doc["version"] == 1
    assert doc["entries"] == {}   # older-version entries are not trusted


def test_cache_refuses_non_cache_json(tmp_path):
    """Pointing --cache at an unrelated JSON artifact must be a loud error,
    never a silent overwrite."""
    with pytest.raises(ValueError, match="not an autotune cache"):
        validate({"entries": {}})
    p = tmp_path / "host_calibration.json"
    p.write_text(json.dumps({"ops": {}, "hardware": "cpu"}))
    with pytest.raises(ValueError, match="not an autotune cache"):
        TuningCache(p)
    assert json.loads(p.read_text())["hardware"] == "cpu"   # untouched


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_tune_show_export_round_trip(tmp_path, capsys):
    cache = str(tmp_path / "cache.json")
    rc = autotune_main(["tune", "--analytic-only",
                        "--kernel", "flash_attention", "--cache", cache])
    assert rc == 0
    rc = autotune_main(["show", "--kernel", "flash_attention",
                        "--cache", cache])
    assert rc == 0
    out = capsys.readouterr().out
    assert "flash_attention|" in out
    # a kernel that was never tuned: show signals it with rc=1
    assert autotune_main(["show", "--kernel", "wkv6", "--cache", cache]) == 1
    exported = tmp_path / "export.json"
    assert autotune_main(["export", str(exported), "--cache", cache]) == 0
    doc = json.loads(exported.read_text())
    assert doc["kind"] == "autotune_cache" and len(doc["entries"]) == 1


def test_cli_tune_with_shape_overrides(tmp_path):
    cache = str(tmp_path / "cache.json")
    rc = autotune_main(["tune", "--analytic-only", "--kernel", "ssm_scan",
                        "--shape", "d_inner=512", "--shape", "seq=128",
                        "--cache", cache])
    assert rc == 0
    entries = list(TuningCache(cache).items("ssm_scan"))
    assert len(entries) == 1
    assert entries[0][1]["shapes"]["d_inner"] == 512


def test_cli_tune_rejects_typoed_shape_axis(tmp_path):
    """A mistyped --shape axis must error, not silently tune defaults."""
    with pytest.raises(SystemExit, match="seqq"):
        autotune_main(["tune", "--analytic-only",
                       "--kernel", "flash_attention",
                       "--shape", "seqq=4096",
                       "--cache", str(tmp_path / "c.json")])


# ---------------------------------------------------------------------------
# kernel dispatch path (ops.py): explicit > config > tuned > default
# ---------------------------------------------------------------------------

def test_resolve_precedence(cm):
    shapes = {"batch": 1, "seq_q": 64, "seq_kv": 64, "heads": 2,
              "kv_heads": 1, "head_dim": 16}
    base = ops.resolve_kernel_config("flash_attention", shapes, jnp.float32)
    assert base == {"block_q": 128, "block_k": 128, "acc_dtype": "f32"}
    got = ops.resolve_kernel_config("flash_attention", shapes, jnp.float32,
                                    config={"block_q": 16, "junk": 1})
    assert got["block_q"] == 16 and "junk" not in got
    got = ops.resolve_kernel_config("flash_attention", shapes, jnp.float32,
                                    config={"block_q": 16},
                                    explicit={"block_q": 32, "block_k": None})
    assert got["block_q"] == 32 and got["block_k"] == 128


def test_tuned_dispatch_hits_installed_autotuner(cm):
    shapes = {"batch": 1, "seq_q": 64, "seq_kv": 64, "heads": 2,
              "kv_heads": 1, "head_dim": 16}
    tuner = Autotuner(cm, dtype="f32")
    res = tuner.tune("flash_attention", shapes)
    with autotune.using(tuner):
        got = ops.resolve_kernel_config("flash_attention", shapes,
                                        jnp.float32, tuned=True)
    assert {k: got[k] for k in res.best} == res.best
    assert tuner.stats.hits == 1
    # without an installed tuner, tuned=True degrades to the defaults
    got = ops.resolve_kernel_config("flash_attention", shapes, jnp.float32,
                                    tuned=True)
    assert got["block_q"] == 128


# ---------------------------------------------------------------------------
# tuned configs stay numerically equivalent to the references
# (odd shapes + both dtypes; decode-equivalence-style tolerances)
# ---------------------------------------------------------------------------

def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 5e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv", [(24, 36), (100, 100), (7, 129)])
def test_tuned_flash_attention_matches_ref(cm, dtype, sq, skv):
    shapes = {"batch": 2, "seq_q": sq, "seq_kv": skv, "heads": 4,
              "kv_heads": 2, "head_dim": 16}
    best = Autotuner(cm).tune("flash_attention", shapes,
                              dtype=str(jnp.dtype(dtype).name)).best
    q = jnp.asarray(RNG.normal(size=(2, sq, 4, 16)), dtype)
    k = jnp.asarray(RNG.normal(size=(2, skv, 2, 16)), dtype)
    v = jnp.asarray(RNG.normal(size=(2, skv, 2, 16)), dtype)
    o = ops.flash_attention(q, k, v, causal=False, config=best)
    r = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=4 * _tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("di,n", [(96, 8), (256, 16)])
def test_tuned_ssm_scan_matches_ref(cm, dtype, di, n):
    shapes = {"batch": 2, "seq": 24, "d_inner": di, "state_dim": n}
    best = Autotuner(cm).tune("ssm_scan", shapes,
                              dtype=str(jnp.dtype(dtype).name)).best
    x = jnp.asarray(RNG.normal(size=(2, 24, di)) * 0.2, dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(2, 24, di)), dtype)
    Bm = jnp.asarray(RNG.normal(size=(2, 24, n)) * 0.2, dtype)
    Cm = jnp.asarray(RNG.normal(size=(2, 24, n)) * 0.2, dtype)
    A = -jnp.abs(jnp.asarray(RNG.normal(size=(di, n)), jnp.float32))
    o = ops.ssm_scan(x, dt, Bm, Cm, A, config=best)
    r = ref.ssm_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=10 * _tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h", [3, 6])
def test_tuned_wkv6_matches_ref(cm, dtype, h):
    N = 16
    shapes = {"batch": 2, "seq": 20, "heads": h, "head_dim": N}
    best = Autotuner(cm).tune("wkv6", shapes,
                              dtype=str(jnp.dtype(dtype).name)).best
    r_ = jnp.asarray(RNG.normal(size=(2, 20, h, N)) * 0.3, dtype)
    k_ = jnp.asarray(RNG.normal(size=(2, 20, h, N)) * 0.3, dtype)
    v_ = jnp.asarray(RNG.normal(size=(2, 20, h, N)) * 0.3, dtype)
    w_ = jnp.asarray(RNG.uniform(0.7, 0.999, size=(2, 20, h, N)), dtype)
    u_ = jnp.asarray(RNG.normal(size=(h, N)) * 0.3, dtype)
    o = ops.wkv6(r_, k_, v_, w_, u_, config=best)
    rr = ref.wkv6_ref(r_, k_, v_, w_, u_)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(rr, np.float32),
                               atol=10 * _tol(dtype))


def test_bf16_accumulator_stays_within_bf16_tolerance(cm):
    """The low-precision accumulator axis (opt-in) must still track the
    reference at bf16 tolerances."""
    tuner = Autotuner(cm, allow_low_precision=True)
    shapes = {"batch": 2, "seq_q": 32, "seq_kv": 48, "heads": 2,
              "kv_heads": 1, "head_dim": 16}
    res = tuner.tune("flash_attention", shapes)
    assert any(r["config"]["acc_dtype"] == "bf16" for r in res.ranked)
    q = jnp.asarray(RNG.normal(size=(2, 32, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 48, 1, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 48, 1, 16)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=False,
                            config={"block_q": 16, "block_k": 16,
                                    "acc_dtype": "bf16"})
    r = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=4 * _tol(jnp.bfloat16))


def test_tuned_mxu_probe_matches_ref(cm):
    shapes = {"m": 128, "k": 128, "n": 96}
    best = Autotuner(cm).tune("mxu_probe", shapes).best
    a = jnp.asarray(RNG.normal(size=(128, 128)) * 0.1, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(128, 96)) * 0.1, jnp.float32)
    o = ops.mxu_probe(a, b, chain=1, config=best)
    r = ref.mxu_probe_ref(a, b, chain=1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-4,
                               rtol=2e-2)


# ---------------------------------------------------------------------------
# measured refinement (tiny problem so interpret mode stays fast)
# ---------------------------------------------------------------------------

def test_mxu_probe_explicit_block_is_strict_but_tuned_clamps(cm):
    """An explicit block= is the measured quantity and must not be
    silently rewritten; a cache/config-resolved block is a perf hint and
    divisor-clamps so bucketed entries can never crash a dispatch."""
    a = jnp.asarray(RNG.normal(size=(200, 64)) * 0.1, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(64, 200)) * 0.1, jnp.float32)
    with pytest.raises(ValueError, match="must divide"):
        ops.mxu_probe(a, b, chain=1, block=(96, 128))
    o = ops.mxu_probe(a, b, chain=1, config={"block_m": 512, "block_n": 96})
    r = ref.mxu_probe_ref(a, b, chain=1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-4,
                               rtol=2e-2)


def test_kernel_defaults_single_sourced():
    """ops.KERNEL_DEFAULTS must be the Tunable registry's defaults — the
    autotuner's 'default' baseline is exactly what dispatch launches."""
    from repro.core.autotune.space import TUNABLES
    assert ops.KERNEL_DEFAULTS == {n: t.default_config
                                   for n, t in TUNABLES.items()}


def test_hit_keys_stay_bounded(cm):
    from repro.core.autotune.search import _HIT_KEYS_KEPT
    tuner = Autotuner(cm)
    tuner.tune("wkv6")
    for _ in range(_HIT_KEYS_KEPT + 40):
        assert tuner.lookup("wkv6", {}) is not None
    assert len(tuner.stats.hit_keys) == _HIT_KEYS_KEPT
    assert tuner.stats.hits == _HIT_KEYS_KEPT + 40


def test_measured_refinement_records_wall_time(cm):
    tuner = Autotuner(cm, measure=True, top_k=2, measure_iters=2,
                      measure_warmup=1)
    shapes = {"m": 64, "k": 64, "n": 64}
    res = tuner.tune("mxu_probe", shapes)
    assert res.source == "measured"
    assert res.measured_best_s is not None and res.measured_best_s > 0
    assert res.measured_default_s is not None
    assert any("measured_s" in r for r in res.ranked)


# ---------------------------------------------------------------------------
# serve + train consume the tuned cache
# ---------------------------------------------------------------------------

def test_engine_consumes_tuned_configs(cm):
    from repro.configs import ARCHS, reduced
    from repro.models.zoo import build_model
    from repro.serve.engine import ServingEngine

    # internlm2: no sliding window, so the flash kernel path is static
    cfg = reduced(ARCHS["internlm2-20b"], n_layers=2, vocab_size=128)
    model_ref = build_model(cfg)
    params = model_ref.init(jax.random.PRNGKey(0))
    model_tuned = build_model(cfg.replace(use_pallas=True))

    prompt = np.arange(5, 13, dtype=np.int32) % cfg.vocab_size
    tuner = Autotuner(cm, dtype="bf16")
    # pre-tune the prefill problem the engine will dispatch (batch=1 slot)
    tuner.tune("flash_attention",
               {"batch": 1, "seq_q": len(prompt), "seq_kv": len(prompt),
                "heads": cfg.padded_heads, "kv_heads": cfg.n_kv_heads,
                "head_dim": cfg.head_dim})

    eng = ServingEngine(model_tuned, params, max_batch=2, max_len=48,
                        autotuner=tuner)
    rid = eng.submit(prompt, max_new_tokens=6)
    eng.run_until_done()

    # the handle is scoped to each step(), never leaked process-globally
    assert autotune.active() is None
    assert tuner.stats.lookups > 0
    assert tuner.stats.hits > 0, "the engine never hit the tuned cache"
    # tuned dispatch must not change the tokens
    eng_ref = ServingEngine(build_model(cfg), params, max_batch=2,
                            max_len=48)
    rid2 = eng_ref.submit(prompt, max_new_tokens=6)
    eng_ref.run_until_done()
    assert eng.done[rid].tokens == eng_ref.done[rid2].tokens


def test_train_consumes_tuned_configs_and_restores_handle(cm):
    from repro.configs import ARCHS, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models.zoo import build_model
    from repro.train.loop import train

    cfg = reduced(ARCHS["internlm2-20b"], n_layers=2, vocab_size=64)
    model = build_model(cfg)
    tuner = Autotuner(cm, dtype="bf16")
    # the train step sees per-microbatch rows: global_batch 4 / accum 2
    tuned = tuner.tune("flash_attention",
                       {"batch": 2, "seq_q": 16, "seq_kv": 16,
                        "heads": cfg.padded_heads,
                        "kv_heads": cfg.n_kv_heads,
                        "head_dim": cfg.head_dim})
    res = train(model, make_host_mesh(), num_steps=2, global_batch=4,
                seq_len=16, autotuner=tuner)
    assert res.steps_run == 2
    # the loop resolved this run's kernel shapes against the tuned cache
    assert res.tuned_configs == {"flash_attention": tuned.best}
    assert tuner.stats.hits > 0, "the train loop never hit the tuned cache"
    assert autotune.active() is None   # handle restored after the run


def test_train_without_autotuner_reports_none():
    from repro.configs import ARCHS, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models.zoo import build_model
    from repro.train.loop import train

    cfg = reduced(ARCHS["internlm2-20b"], n_layers=2, vocab_size=64)
    res = train(build_model(cfg), make_host_mesh(), num_steps=1,
                global_batch=4, seq_len=16)
    assert res.tuned_configs is None
