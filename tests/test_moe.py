"""MoE routing invariants — unit + hypothesis property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models.layers import moe as M


def _cfg(E=4, K=2, cf=1.0, shared=0):
    cfg = reduced(ARCHS["olmoe-1b-7b"])
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, n_experts=E, top_k=K, capacity_factor=cf, n_shared=shared))


def test_route_positions_within_capacity():
    S, E, K, C = 32, 4, 2, 8
    logits = jax.random.normal(jax.random.PRNGKey(0), (S, E))
    gates, eid, slot, keep = M._route(logits, K, C)
    slot = np.asarray(slot)
    keep = np.asarray(keep)
    assert (slot[keep] < C).all()
    # kept slots are unique per expert
    eid = np.asarray(eid)
    seen = set()
    for s in range(S):
        for k in range(K):
            if keep[s, k]:
                key = (eid[s, k], slot[s, k])
                assert key not in seen
                seen.add(key)


def test_gates_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    gates, *_ = M._route(logits, 3, 8)
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, atol=1e-5)


def test_moe_ffn_runs_and_is_finite():
    cfg = _cfg(shared=1)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)
                          ).astype(jnp.bfloat16)
    out, aux = M.moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(aux["moe_load_balance"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz


def test_high_capacity_matches_dense_mixture():
    """With capacity so large nothing drops, MoE == explicit per-token
    mixture of expert MLPs."""
    cfg = _cfg(E=4, K=2, cf=16.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model)
                          ).astype(jnp.float32)
    out, _ = M.moe_ffn(p, x, cfg)

    logits = np.asarray(jnp.einsum("bsd,de->bse", x, p["router"]))
    probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))[0]
    expect = np.zeros_like(np.asarray(x))[0]
    for s in range(8):
        top = np.argsort(-probs[s])[:2]
        g = probs[s][top] / probs[s][top].sum()
        for gi, e in zip(g, top):
            xe = jnp.asarray(x[0, s:s+1][None])
            h = np.asarray(jax.nn.silu(xe @ p["w_gate"][e]) * (xe @ p["w_up"][e])
                           @ p["w_down"][e])[0, 0]
            expect[s] += gi * h
    np.testing.assert_allclose(np.asarray(out)[0], expect, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_route_keep_is_prefix_of_expert_arrivals(E, K, seed):
    """Property: overflow drops the LATEST arrivals (token order priority)."""
    K = min(K, E)
    S, C = 24, 8
    logits = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (S, E))
    gates, eid, slot, keep = M._route(logits, K, C)
    eid, slot, keep = map(np.asarray, (eid, slot, keep))
    for e in range(E):
        arrivals = [(s, k) for s in range(S) for k in range(K)
                    if eid[s, k] == e]
        kept = [keep[s, k] for s, k in arrivals]
        # all kept arrivals precede all dropped ones
        assert kept == sorted(kept, reverse=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_capacity_factor_monotone_in_drops(seed):
    cfg_lo = _cfg(cf=0.25)
    cfg_hi = _cfg(cf=8.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg_lo)
    x = jax.random.normal(jax.random.PRNGKey(seed % 2**31),
                          (1, 32, cfg_lo.d_model)).astype(jnp.float32)
    out_lo, _ = M.moe_ffn(p, x, cfg_lo)
    out_hi, _ = M.moe_ffn(p, x, cfg_hi)
    # low capacity zeroes some tokens' routed output -> smaller norm
    assert (np.linalg.norm(np.asarray(out_lo))
            <= np.linalg.norm(np.asarray(out_hi)) + 1e-3)
